"""Kernel micro-benchmarks: Pallas (interpret) vs oracle + model-predicted
traffic for the tile choices (analytic; wall-clock on CPU is NOT the TPU
story, so the derived column reports the model's DRAM-traffic ratio),
plus autotuned-vs-hardcoded tile comparisons on the same access model —
for the FORWARD kernels, (ISSUE 2) the custom-VJP BACKWARD nests, and
(ISSUE 4) the QUANTIZED variants (matmul_w8 under its dtype-aware
schedule key), so the BENCH json carries training- and quantization-cost
axes.  ``--dtype`` picks the activation dtype the forward-GEMM
comparisons (incl. matmul_w8) run at — float32 default, bfloat16
mirrors the TPU deployment width; the conv/backward/attention sections
stay float32."""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed, write_json
from repro.core import (BlockingString, Dim, Loop, Problem, matmul_tiles)
from repro.kernels import ops, ref
from repro.tune import OpSpec, best_schedule, predicted_dram_accesses


def matmul_traffic_ratio(m, n, k) -> float:
    """Model-predicted HBM traffic under a VMEM-sized on-chip level:
    optimizer tile vs untiled GEMM (whose working set spills)."""
    from repro.core import MemLevel, cache_accesses
    levels = [MemLevel.sram("VMEM", 16 * 1024 * 1024), MemLevel.dram()]
    p = Problem.gemm(M=m, N_cols=n, K_reduce=k)
    bm, bk, bn = matmul_tiles(m, n, k, 2)
    tiled = BlockingString(
        [Loop(Dim.C, bk), Loop(Dim.X, bm), Loop(Dim.K, bn),
         Loop(Dim.C, k), Loop(Dim.K, n), Loop(Dim.X, m)], p)
    naive = BlockingString(
        [Loop(Dim.C, k), Loop(Dim.K, n), Loop(Dim.X, m)], p)
    naive_dram = cache_accesses(naive, levels)["DRAM"]
    tiled_dram = cache_accesses(tiled, levels)["DRAM"]
    return naive_dram / max(tiled_dram, 1)


# hardcoded tiles this benchmark shipped with before the autotuner; kept
# as the baseline the tuned schedules are compared against
DEFAULT_MATMUL_TILES = (64, 128, 128)
DEFAULT_CONV_TILES = (13, 13, 32, 64)
DEFAULT_CONV_DGRAD_TILES = (14, 14, 64, 32)


def tuned_vs_default(spec: OpSpec, default_tiles) -> tuple[tuple, str]:
    """Tuned tiles + a derived-column string comparing DRAM accesses."""
    sched = best_schedule(spec.op, spec.dims, spec.dtype,
                          stride=spec.stride)
    tuned = predicted_dram_accesses(spec, sched.tiles)
    default = predicted_dram_accesses(spec, default_tiles)
    verdict = "BEATS" if tuned < default else \
        "matches" if tuned == default else "LOSES-TO"
    return sched.tiles, (f"tuned {sched.tiles} {tuned:.3e} {verdict} "
                         f"default {default_tiles} {default:.3e} "
                         f"DRAM accesses ({sched.source})")


def _mlp_chain_measured_bytes(M: int, D: int, F: int, bpe: int,
                              t_up, t_down, fused: bool) -> int:
    """Exact HBM traffic of the MLP-block chain as the kernels execute
    it (grid block transfers; see ``matmul_fused.hbm_bytes``).  Unfused:
    two plain GEMMs + a standalone GELU pass (read + write M*F) + a
    standalone residual add (2 reads + 1 write of M*D).  Fused: the
    same two GEMMs with the activation absorbed into the first epilogue
    and the residual streamed into the second."""
    from repro.kernels.matmul_fused import hbm_bytes
    up = hbm_bytes(M, F, D, *t_up, bytes_per_elem=bpe)
    down = hbm_bytes(M, D, F, *t_down, bytes_per_elem=bpe,
                     has_residual=fused)
    total = up + down
    if not fused:
        total += 2 * M * F * bpe          # standalone GELU round trip
        total += 3 * M * D * bpe          # residual add: 2 reads + write
    return total


def run_fused(dtype: str = "float32", smoke: bool = False) -> None:
    """Cross-op fusion section (ISSUE 5): the fused MLP-block chain and
    the one-pass QKV projection vs their per-op chains — correctness vs
    the unfused ops, measured DRAM bytes (the kernels' exact grid
    transfers), and the analytical model's predicted savings, which
    must agree in sign and rank with measurement for every config."""
    from repro.core.fusion import FusedProblem, optimize_fused
    from repro.kernels import qkv_fused as qkv_mod
    from repro.tune import vmem_budget

    rng = np.random.default_rng(0)
    jdt = getattr(jnp, dtype)
    bpe = jnp.dtype(jdt).itemsize
    rtol, atol = (2e-2, 2e-2) if dtype == "bfloat16" else (1e-4, 1e-4)
    budget = vmem_budget()

    configs = [(64, 128, 256)] if smoke else \
        [(128, 256, 512), (256, 256, 1024), (256, 512, 512)]
    rows = []
    for M, D, F in configs:
        x = jnp.asarray(rng.normal(size=(M, D)), jdt)
        w_up = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jdt)
        w_down = jnp.asarray(rng.normal(size=(F, D)) * 0.1, jdt)
        h = jnp.asarray(rng.normal(size=(M, D)), jdt)

        t_up = best_schedule("matmul_fused", (M, F, D), dtype).tiles
        t_down = best_schedule("matmul_fused", (M, D, F), dtype).tiles

        # unfused per-op chain (the baseline the fusion replaces)
        u = ops.matmul(x, w_up, tiles=t_up, interpret=True)
        g = jax.nn.gelu(u.astype(jnp.float32)).astype(jdt)
        out_ref = h + ops.matmul(g, w_down, tiles=t_down, interpret=True)

        # fused chain: two kernels, zero elementwise round-trips
        def fused_chain():
            a = ops.matmul_fused(x, w_up, act="gelu", tiles=t_up,
                                 use_kernel=True, interpret=True)
            return ops.matmul_fused(a, w_down, residual=h, tiles=t_down,
                                    use_kernel=True, interpret=True)

        us, out = timed(lambda: np.asarray(fused_chain()))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(out_ref, np.float32),
                                   rtol=rtol, atol=atol)

        meas_unfused = _mlp_chain_measured_bytes(M, D, F, bpe, t_up,
                                                 t_down, fused=False)
        meas_fused = _mlp_chain_measured_bytes(M, D, F, bpe, t_up,
                                               t_down, fused=True)
        assert meas_fused < meas_unfused, (meas_fused, meas_unfused)

        fp = FusedProblem.mlp(M, D, F, bytes_per_elem=bpe)
        best = optimize_fused(fp, budget)[0]
        assert best.savings_bytes > 0, best.summary()
        rows.append((M, D, F, meas_unfused - meas_fused,
                     best.savings_bytes))
        emit(f"kernel/mlp_chain_fused_m{M}d{D}f{F}_{dtype}", us,
             f"measured DRAM {meas_fused:.3e}B vs unfused "
             f"{meas_unfused:.3e}B; model predicts "
             f"{best.savings_bytes:.3e}B saved "
             f"({100 * best.savings_frac:.0f}%)",
             measured_fused_bytes=meas_fused,
             measured_unfused_bytes=meas_unfused,
             modeled_fused_bytes=best.fused_bytes,
             modeled_unfused_bytes=best.unfused_bytes)

    # sign agreed above (both savings > 0); rank must agree too
    by_meas = sorted(rows, key=lambda r: r[3])
    by_model = sorted(rows, key=lambda r: r[4])
    assert [r[:3] for r in by_meas] == [r[:3] for r in by_model], \
        ("model/measurement savings rank disagree", rows)

    # one-pass QKV: the activation streams once instead of three times
    M, D = (32, 128) if smoke else (128, 256)
    hkv_w, g_q = D // 2, 2
    x = jnp.asarray(rng.normal(size=(M, D)), jdt)
    wq = jnp.asarray(rng.normal(size=(D, g_q * hkv_w)) * 0.1, jdt)
    wk = jnp.asarray(rng.normal(size=(D, hkv_w)) * 0.1, jdt)
    wv = jnp.asarray(rng.normal(size=(D, hkv_w)) * 0.1, jdt)
    tq = best_schedule("qkv_fused", (M, hkv_w, D, g_q), dtype).tiles
    us, (q_o, k_o, v_o) = timed(lambda: tuple(
        np.asarray(t) for t in ops.qkv_fused(
            x, wq, wk, wv, tiles=tq, use_kernel=True, interpret=True)))
    for got, w in ((q_o, wq), (k_o, wk), (v_o, wv)):
        np.testing.assert_allclose(
            got.astype(np.float32),
            np.asarray(ops.matmul(x, w, interpret=True), np.float32),
            rtol=rtol, atol=atol)
    meas_fused = qkv_mod.hbm_bytes(M, hkv_w, D, g_q, *tq,
                                   bytes_per_elem=bpe)
    from repro.kernels.matmul_fused import hbm_bytes as mm_bytes
    meas_unfused = 0
    for n in (g_q * hkv_w, hkv_w, hkv_w):
        t = best_schedule("matmul", (M, n, D), dtype).tiles
        meas_unfused += mm_bytes(M, n, D, *t, bytes_per_elem=bpe)
    emit(f"kernel/qkv_fused_m{M}d{D}_{dtype}", us,
         f"measured DRAM {meas_fused:.3e}B vs 3-GEMM "
         f"{meas_unfused:.3e}B"
         + (" BEATS" if meas_fused < meas_unfused else " LOSES-TO"),
         measured_fused_bytes=meas_fused,
         measured_unfused_bytes=meas_unfused)

    # oproj-fused flash decode, per request (B=1): the (Hq, hd)
    # attention output never exists in HBM; the unfused pair writes it
    # and reads it back for the projection GEMM.  (At B>1 the fused
    # kernel refetches the wo slab per batch row — docs/fusion.md's
    # "when fusion loses" arithmetic — so the per-request view is the
    # honest one.)
    from repro.kernels.flash_decode import (flash_decode_oproj,
                                            oproj_hbm_bytes,
                                            paged_attention_oproj_ref)
    hkv, g_d, hd, E = (2, 2, 16, 64) if smoke else (2, 4, 32, 256)
    seq = 32 if smoke else 128
    sched = best_schedule("flash_decode_oproj", (g_d, seq, hd, E), dtype)
    page = sched.tiles[0]
    nb = seq // page
    q = jnp.asarray(rng.normal(size=(1, hkv, g_d, hd)), jdt)
    kp = jnp.asarray(rng.normal(size=(nb + 1, page, hkv, hd)), jdt)
    vp = jnp.asarray(rng.normal(size=(nb + 1, page, hkv, hd)), jdt)
    bt = jnp.asarray(1 + rng.permutation(nb).reshape(1, nb), jnp.int32)
    lengths = jnp.asarray([seq - 3], jnp.int32)
    wo = jnp.asarray(rng.normal(size=(hkv, g_d * hd, E)) * 0.1, jdt)
    us, out = timed(lambda: np.asarray(flash_decode_oproj(
        q, kp, vp, bt, lengths, wo, interpret=True)))
    np.testing.assert_allclose(
        out.astype(np.float32),
        np.asarray(paged_attention_oproj_ref(q, kp, vp, bt, lengths, wo),
                   np.float32), rtol=rtol, atol=atol)
    meas_fused = oproj_hbm_bytes(1, hkv, g_d, hd, E, seq, page,
                                 bytes_per_elem=bpe)
    # unfused: identical decode + wo + output traffic, PLUS the
    # attention-output intermediate's write + read-back
    attn_rt = 2 * hkv * g_d * hd * bpe
    meas_unfused = meas_fused + attn_rt
    assert meas_fused < meas_unfused
    emit(f"kernel/flash_decode_oproj_s{seq}e{E}_{dtype}", us,
         f"measured DRAM {meas_fused:.3e}B vs unfused pair "
         f"{meas_unfused:.3e}B (page {page}, per request)",
         measured_fused_bytes=meas_fused,
         measured_unfused_bytes=meas_unfused, page_size=int(page))


def run(dtype: str = "float32") -> None:
    rng = np.random.default_rng(0)
    jdt = getattr(jnp, dtype)
    # interpret-mode kernels accumulate fp32 either way; tolerances track
    # the activation width the comparison runs at
    rtol, atol = (2e-2, 2e-2) if dtype == "bfloat16" else (1e-3, 1e-3)
    # matmul: hardcoded-default tiles vs the autotuner's pick
    a = jnp.asarray(rng.normal(size=(256, 512)), jdt)
    b = jnp.asarray(rng.normal(size=(512, 256)), jdt)
    ref_out = np.asarray(ref.matmul_ref(a, b), np.float32)
    out = ops.matmul(a, b, tiles=DEFAULT_MATMUL_TILES, interpret=True)
    us, _ = timed(lambda: np.asarray(
        ops.matmul(a, b, tiles=DEFAULT_MATMUL_TILES, interpret=True)))
    ratio = matmul_traffic_ratio(4096, 4096, 4096)
    emit(f"kernel/matmul_256x512x256_{dtype}", us,
         f"model DRAM-traffic reduction (4k GEMM) {ratio:.1f}x")
    np.testing.assert_allclose(np.asarray(out, np.float32), ref_out,
                               rtol=rtol, atol=atol)

    mm_spec = OpSpec("matmul", (256, 256, 512), dtype)
    mm_tiles, derived = tuned_vs_default(mm_spec, DEFAULT_MATMUL_TILES)
    us, tuned_out = timed(lambda: np.asarray(
        ops.matmul(a, b, tiles=mm_tiles, interpret=True)))
    np.testing.assert_allclose(np.asarray(tuned_out, np.float32), ref_out,
                               rtol=rtol, atol=atol)
    emit(f"kernel/matmul_256x512x256_tuned_{dtype}", us, derived)

    # QUANTIZED variant: same dims, int8 weight stream, own schedule key
    # — the dtype-aware model ranks its tiles against 1-byte weights
    from repro.kernels.matmul_q import matmul_w8_ref
    from repro.quant import quantize
    w8_spec = OpSpec("matmul_w8", (256, 256, 512), dtype)
    w8_tiles, w8_derived = tuned_vs_default(w8_spec, DEFAULT_MATMUL_TILES)
    qt = quantize(b.astype(jnp.float32), "int8")
    scale = qt.scale.reshape(-1)
    us, q_out = timed(lambda: np.asarray(
        ops.matmul_w8(a, qt.q, scale, tiles=w8_tiles, interpret=True)))
    np.testing.assert_allclose(
        np.asarray(q_out, np.float32),
        np.asarray(matmul_w8_ref(a, qt.q, scale), np.float32),
        rtol=rtol, atol=atol)
    emit(f"kernel/matmul_w8_256x512x256_tuned_{dtype}", us, w8_derived)

    # matmul BACKWARD: the two dgrad nests (dA: (M,K,N); dB: (K,N,M)),
    # tuned vs the hardcoded default on predicted DRAM accesses, plus the
    # end-to-end jax.grad wall time through the custom-VJP Pallas kernels
    da_spec = OpSpec("matmul_dgrad", (256, 512, 256), "float32")
    _, da_derived = tuned_vs_default(da_spec, DEFAULT_MATMUL_TILES)
    db_spec = OpSpec("matmul_dgrad", (512, 256, 256), "float32")
    _, db_derived = tuned_vs_default(db_spec, DEFAULT_MATMUL_TILES)
    grad_fn = jax.grad(
        lambda a, b: jnp.sum(ops.matmul(a, b, interpret=True) ** 2),
        argnums=(0, 1))
    # backward stays float32 whatever --dtype drives the forward section
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    us, _ = timed(lambda: jax.tree.map(np.asarray, grad_fn(af, bf)))
    emit("kernel/matmul_256x512x256_bwd", us,
         f"dA {da_derived}; dB {db_derived}")

    # conv
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 32, 64)), jnp.float32)
    us, out = timed(lambda: np.asarray(
        ops.conv2d(x, w, tiles=DEFAULT_CONV_TILES, interpret=True)))
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-2,
                               atol=1e-2)
    emit("kernel/conv_28x28x32x64", us, "allclose-vs-oracle OK")

    conv_spec = OpSpec("conv2d", (26, 26, 32, 64, 3, 3), "float32")
    cv_tiles, derived = tuned_vs_default(conv_spec, DEFAULT_CONV_TILES)
    us, tuned_out = timed(lambda: np.asarray(
        ops.conv2d(x, w, tiles=cv_tiles, interpret=True)))
    np.testing.assert_allclose(tuned_out, ref.conv2d_ref(x, w), rtol=1e-2,
                               atol=1e-2)
    emit("kernel/conv_28x28x32x64_tuned", us, derived)

    # conv BACKWARD: wgrad shares the forward dims; dgrad is the
    # transposed conv (28x28 output space, channels swapped)
    wg_spec = OpSpec("conv2d_wgrad", (26, 26, 32, 64, 3, 3), "float32")
    _, wg_derived = tuned_vs_default(wg_spec, DEFAULT_CONV_TILES)
    dg_spec = OpSpec("conv2d_dgrad", (28, 28, 64, 32, 3, 3), "float32")
    _, dg_derived = tuned_vs_default(dg_spec, DEFAULT_CONV_DGRAD_TILES)
    conv_grad = jax.grad(
        lambda x, w: jnp.sum(ops.conv2d(x, w, interpret=True) ** 2),
        argnums=(0, 1))
    us, _ = timed(lambda: jax.tree.map(np.asarray, conv_grad(x, w)))
    emit("kernel/conv_28x28x32x64_bwd", us,
         f"wgrad {wg_derived}; dgrad {dg_derived}")

    # attention
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    us, out = timed(lambda: np.asarray(
        ops.attention(q, k, v, tiles=(32, 32), interpret=True)))
    emit("kernel/flash_attn_128", us, "GQA causal OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="activation dtype for the forward-GEMM "
                         "tuned-vs-default comparisons, incl. the "
                         "quantized matmul_w8 variant (int8 weight "
                         "stream either way); the conv/backward/"
                         "attention sections stay float32")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: the fused section only, at "
                         "reduced shapes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every record as machine-readable "
                         "JSON (the BENCH_kernels.json trajectory file)")
    args = ap.parse_args()
    if not args.smoke:
        run(dtype=args.dtype)
    run_fused(dtype=args.dtype, smoke=args.smoke)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
