"""Serving benchmark: paged continuous batching vs the static-batch
baseline on a mixed-length workload.

Workload: ``--requests`` prompts with lengths in [prompt_len/2,
prompt_len] and *heavy-tailed* generation budgets (75% short answers,
25% long ones up to ``--gen``) — the output-length skew real serving
traffic has.  The static engine processes requests in submission-order
batches, left-padding prompts to the batch max and decoding every batch
member to the batch's largest budget (tokens past a request's own budget
are discarded — the lock-step waste continuous batching removes).  The
paged engine streams the same requests through its decode slots,
admitting by free-page budget and evicting the moment a request
finishes.

Every run — ``--smoke`` included — uses a serving-scale reduced config
(d_model 256): on the tiny test config per-step compute is smaller than
a host dispatch and the comparison would measure dispatch counts, not
scheduling.  ``--smoke`` only shrinks the *workload* to CI size.

``--prefix-cache`` adds a *prompt-reuse* section on its own zipfian
workload (a small pool of shared prefixes with zipf(1.2) popularity,
unique ragged tails): the prefix-sharing paged engine against an
otherwise-identical engine with sharing off, pinned to the same page
size.  Its records carry ``cache_hit_rate`` and
``admitted_tokens_saved`` — and are *not* comparable to the
``serve_static`` baseline, which runs the mixed-length workload.

Reports decode tokens/sec (useful tokens only) and p50/p95/p99
per-token step latency.  CSV contract: ``name,us_per_call,derived``.
Every record embeds the engine's metrics snapshot (registry counters +
the modeled-vs-measured DRAM report) under a ``metrics`` field;
``check_bench.py`` ignores fields it doesn't guard, so snapshot schema
growth never forces an ``--update``.  ``--trace`` / ``--metrics-out`` /
``--miss-log`` wire a full :class:`repro.obs.Obs` into the measured
paged engine (tracing inserts device fences — don't trust traced
throughput numbers; see docs/observability.md).

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, latency_summary, write_json
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.obs import Obs
from repro.serve.engine import (DecodeEngine, PagedEngine, PagedServeConfig,
                                ServeConfig)


def make_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                  seed: int = 0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(max(1, prompt_len // 2), prompt_len + 1,
                        n_requests)
    # heavy-tailed budgets: mostly short answers, occasional stragglers
    short = rng.integers(2, max(3, gen // 8), n_requests)
    long = rng.integers(max(2, gen // 2), gen + 1, n_requests)
    gens = np.where(rng.random(n_requests) < 0.75, short, long)
    prompts = [rng.integers(0, cfg.vocab, (int(L),), dtype=np.int32)
               for L in lens]
    return prompts, [int(g) for g in gens]


def make_reuse_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                        max_seq: int, seed: int = 1):
    """Zipfian prompt-reuse workload for the prefix-cache section.

    A small pool of shared prefixes (3/4 of ``prompt_len`` tokens) is
    drawn once; each request picks a prefix with zipf(1.2) popularity —
    a few prompts dominate, like templated system prompts do — and
    appends a unique ragged tail (possibly empty, which exercises the
    exact-full-match CoW fork).  Budgets are heavy-tailed like
    :func:`make_workload`, capped so prompt + generation fits
    ``max_seq``.
    """
    rng = np.random.default_rng(seed)
    pre_len = max(1, (3 * prompt_len) // 4)
    pool = [rng.integers(0, cfg.vocab, (pre_len,), dtype=np.int32)
            for _ in range(8)]
    ranks = np.minimum(rng.zipf(1.2, n_requests) - 1, len(pool) - 1)
    short = rng.integers(2, max(3, gen // 8), n_requests)
    long = rng.integers(max(2, gen // 2), gen + 1, n_requests)
    gens = np.where(rng.random(n_requests) < 0.75, short, long)
    prompts, capped = [], []
    for r, g in zip(ranks, gens):
        tail_len = int(rng.integers(0, prompt_len - pre_len + 1))
        tail = rng.integers(0, cfg.vocab, (tail_len,), dtype=np.int32)
        prompt = np.concatenate([pool[int(r)], tail])
        prompts.append(prompt)
        capped.append(int(max(2, min(int(g), max_seq - len(prompt)))))
    return prompts, capped


def run_static(engine, prompts, gens, max_batch: int):
    """Submission-order batches, padded prompts, lock-step decode."""
    useful = 0
    step_times = []
    t0 = time.perf_counter()
    for i in range(0, len(prompts), max_batch):
        chunk_p = prompts[i:i + max_batch]
        chunk_g = gens[i:i + max_batch]
        width = max(p.shape[0] for p in chunk_p)
        batch = np.zeros((len(chunk_p), width), np.int32)
        for j, p in enumerate(chunk_p):        # right-aligned (left pad)
            batch[j, width - p.shape[0]:] = p
        n_tok = max(chunk_g)
        tb = time.perf_counter()
        engine.generate(batch, n_tok)
        dt = time.perf_counter() - tb
        step_times += [dt / n_tok] * n_tok     # lock-step: uniform
        useful += sum(chunk_g)
    wall = time.perf_counter() - t0
    return wall, useful, step_times


def run_paged(engine, prompts, gens):
    for p, g in zip(prompts, gens):
        engine.submit(p, g)
    useful = 0
    step_times = []
    t0 = time.perf_counter()
    while engine.has_work:
        tb = time.perf_counter()
        for req in engine.step():
            useful += req.generated
        dt = time.perf_counter() - tb
        # one scheduler visit emits up to decode_chunk tokens per slot
        # (more with speculative decode); normalize to per-token latency
        step_times += [dt / max(engine.last_step_tokens, 1)] * \
            max(engine.last_step_tokens, 1)
    wall = time.perf_counter() - t0
    return wall, useful, step_times


def paged_fields(engine, spec_before=None, prefix_before=None):
    """Per-engine configuration + speculative-decode acceptance and
    prefix-cache stats for the JSON record (deltas against pre-warmup
    snapshots so warmup runs don't pollute the measured run).  Every
    paged record carries ``cache_hit_rate`` / ``admitted_tokens_saved``
    — zero for engines without prefix caching — so the trajectory file
    stays one schema."""
    fields = {"page_size": int(engine.page_size),
              "prefill_chunk": int(engine.prefill_chunk),
              "spec_decode": int(engine.spec)}
    if engine.spec:
        st = engine.spec_stats()
        calls = st["verify_calls"] - (spec_before or {}).get(
            "verify_calls", 0)
        toks = st["tokens"] - (spec_before or {}).get("tokens", 0)
        fields["spec_verify_calls"] = int(calls)
        fields["spec_mean_accepted"] = round(toks / calls, 3) if calls \
            else 0.0
    fields["prefix_cache"] = bool(engine.prefix_caching)
    if engine.prefix_caching:
        st = engine.prefix_stats()
        b = prefix_before or {}
        lookups = st["lookups"] - b.get("lookups", 0)
        hits = st["hits"] - b.get("hits", 0)
        fields["cache_hit_rate"] = round(hits / lookups, 3) if lookups \
            else 0.0
        fields["admitted_tokens_saved"] = int(
            st["tokens_saved"] - b.get("tokens_saved", 0))
    else:
        fields["cache_hit_rate"] = 0.0
        fields["admitted_tokens_saved"] = 0
    return fields


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=96)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (same serving-scale model)")
    ap.add_argument("--fuse", action="store_true",
                    help="also run the paged engine with cross-op "
                         "fused kernels (docs/fusion.md) and report a "
                         "fused-vs-unfused section")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="also run a zipfian prompt-reuse section: "
                         "prefix-sharing paged engine vs an identical "
                         "engine with sharing off (docs/serving.md)")
    ap.add_argument("--reuse-hint", type=float, default=0.5,
                    help="reuse rate fed to the share-vs-stream "
                         "page-size pricing for the sharing engine")
    ap.add_argument("--preempt", action="store_true",
                    help="also run a preemption/restore section: a "
                         "starved high-priority arrival preempts a "
                         "low-priority hog, whose restore replays only "
                         "the unshared tail (docs/robustness.md)")
    ap.add_argument("--spec", type=int, default=2,
                    help="draft tokens per speculative decode step for "
                         "the paged engine (0 -> off)")
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="decode steps fused per scheduler visit; small "
                         "chunks turn slots over faster on heavy-tailed "
                         "budgets (finished slots leave, queued work "
                         "enters, between chunks)")
    ap.add_argument("--prefill-chunk", type=int, default=-1,
                    help="paged prefill chunk (-1 -> auto-sized from "
                         "the VMEM blocking model, 0 -> whole-prompt "
                         "joins)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every record as machine-readable "
                         "JSON (the BENCH_serve.json trajectory file)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="Chrome-trace span timeline for the measured "
                         "paged engine; inserts device fences, so "
                         "traced throughput numbers are NOT comparable")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the measured paged engine's metrics "
                         "snapshot (registry + DRAM report) as JSON")
    ap.add_argument("--miss-log", default=None, metavar="PATH",
                    help="append schedule-cache misses as JSONL targets "
                         "for python -m repro.tune --from-telemetry")
    args = ap.parse_args()
    if args.smoke:
        # large enough that per-step latency percentiles are taken over
        # dozens of steps, the heavy-tailed budget draw can't collapse
        # the whole workload to a handful of useful tokens (the old
        # 6-request/gen-8 draw bottomed out at useful=23), and the
        # batch is wide enough that lock-step padding waste — the thing
        # continuous batching exists to remove — actually shows up
        args.requests, args.gen, args.prompt_len = 16, 48, 16
        args.max_seq, args.max_batch = 64, 4

    cfg = dataclasses.replace(get_reduced(args.arch), dtype=jnp.float32)
    # serving-scale reduced model: per-step compute must dominate host
    # dispatch for the throughput comparison to mean anything
    cfg = dataclasses.replace(cfg, d_model=256, n_layers=4,
                              n_heads=8, n_kv_heads=4, d_ff=1024,
                              vocab=4096)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts, gens = make_workload(cfg, args.requests, args.prompt_len,
                                  args.gen)

    chunk = None if args.prefill_chunk < 0 else args.prefill_chunk
    static = DecodeEngine(cfg, params, ServeConfig(max_seq=args.max_seq))
    obs = Obs(trace=args.trace, miss_log=args.miss_log)
    paged = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=args.max_seq, max_batch=args.max_batch,
        page_size=args.page_size or None, prefill_chunk=chunk,
        spec_decode=args.spec, decode_chunk=args.decode_chunk), obs=obs)

    # warm the compile caches outside the timed region: one full pass of
    # the same workload per engine (compiles are keyed by batch width,
    # token budget and prefill bucket — the workload exercises them all)
    run_static(static, prompts, gens, args.max_batch)
    run_paged(paged, prompts, gens)
    spec0 = paged.spec_stats() if paged.spec else None

    s_wall, s_useful, s_steps = run_static(static, prompts, gens,
                                           args.max_batch)
    p_wall, p_useful, p_steps = run_paged(paged, prompts, gens)
    page = paged.page_size
    assert p_useful == sum(gens), (p_useful, sum(gens))

    s_tps = s_useful / s_wall
    p_tps = p_useful / p_wall
    s_lat, s_lat_f = latency_summary(s_steps)
    p_lat, p_lat_f = latency_summary(p_steps)
    emit("serve_static", s_wall / max(s_useful, 1) * 1e6,
         f"{s_tps:.1f} tok/s {s_lat} useful={s_useful}",
         tok_s=round(s_tps, 2), **s_lat_f,
         useful_tokens=int(s_useful),
         metrics=static.obs.snapshot())
    emit("serve_paged", p_wall / max(p_useful, 1) * 1e6,
         f"{p_tps:.1f} tok/s {p_lat} "
         f"useful={p_useful} page={page} chunk={paged.prefill_chunk} "
         f"spec={paged.spec} speedup={p_tps / max(s_tps, 1e-9):.2f}x",
         tok_s=round(p_tps, 2), **p_lat_f,
         useful_tokens=int(p_useful),
         metrics=paged.obs.snapshot(),
         **paged_fields(paged, spec0))

    if args.fuse:
        # fused-vs-unfused paged section: same workload, same slots,
        # cross-op fused kernels on the hot path; greedy decoding makes
        # the outputs comparable token-for-token with the run above
        fused = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=args.max_seq, max_batch=args.max_batch,
            page_size=args.page_size or None, fuse=True,
            prefill_chunk=chunk, spec_decode=args.spec,
            decode_chunk=args.decode_chunk))
        run_paged(fused, prompts, gens)          # warm compiles
        fspec0 = fused.spec_stats() if fused.spec else None
        f_wall, f_useful, f_steps = run_paged(fused, prompts, gens)
        assert f_useful == sum(gens), (f_useful, sum(gens))
        f_tps = f_useful / f_wall
        f_lat, f_lat_f = latency_summary(f_steps)
        emit("serve_paged_fused", f_wall / max(f_useful, 1) * 1e6,
             f"{f_tps:.1f} tok/s {f_lat} "
             f"useful={f_useful} page={fused.page_size} "
             f"vs-unfused={f_tps / max(p_tps, 1e-9):.2f}x",
             tok_s=round(f_tps, 2), **f_lat_f,
             useful_tokens=int(f_useful),
             metrics=fused.obs.snapshot(),
             **paged_fields(fused, fspec0))

    if args.prefix_cache:
        # prompt-reuse section: zipf-popular shared prefixes on a
        # separate workload (NOT comparable to serve_static above).
        # The sharing engine prices its page size under the reuse hint;
        # the no-sharing engine is pinned to the SAME page size, so the
        # delta is purely the sharing machinery — hit admissions skip
        # the shared prefix's prefill and only stream the tail
        # long prompts, short answers — the templated-system-prompt
        # regime sharing targets; a miss pays a near-max_seq join, a
        # hit streams only its ragged tail
        r_plen = 3 * args.max_seq // 4
        r_prompts, r_gens = make_reuse_workload(
            cfg, args.requests, r_plen, args.gen, args.max_seq)
        share = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=args.max_seq, max_batch=args.max_batch,
            page_size=args.page_size or None, prefill_chunk=chunk,
            spec_decode=args.spec, decode_chunk=args.decode_chunk,
            prefix_cache=True, reuse_hint=args.reuse_hint))
        noshare = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=args.max_seq, max_batch=args.max_batch,
            page_size=share.page_size, prefill_chunk=chunk,
            spec_decode=args.spec, decode_chunk=args.decode_chunk))
        run_paged(noshare, r_prompts, r_gens)    # warm compiles
        nspec0 = noshare.spec_stats() if noshare.spec else None
        n_wall, n_useful, n_steps = run_paged(noshare, r_prompts, r_gens)
        assert n_useful == sum(r_gens), (n_useful, sum(r_gens))
        # the sharing engine's warmup also brings the radix tree to
        # steady state — the measured run sees a warm cache, which is
        # the regime prefix caching exists for; the second pass repeats
        # the workload against the now-warm tree so every all-hit
        # admission path (and its span-width compile) runs before the
        # clock starts; stats are deltas
        run_paged(share, r_prompts, r_gens)
        run_paged(share, r_prompts, r_gens)
        sspec0 = share.spec_stats() if share.spec else None
        spfx0 = share.prefix_stats()
        sh_wall, sh_useful, sh_steps = run_paged(share, r_prompts,
                                                 r_gens)
        assert sh_useful == sum(r_gens), (sh_useful, sum(r_gens))
        n_tps = n_useful / n_wall
        sh_tps = sh_useful / sh_wall
        n_lat, n_lat_f = latency_summary(n_steps)
        h_lat, h_lat_f = latency_summary(sh_steps)
        emit("serve_paged_noshare", n_wall / max(n_useful, 1) * 1e6,
             f"{n_tps:.1f} tok/s {n_lat} "
             f"useful={n_useful} page={noshare.page_size} "
             f"(reuse workload, sharing off)",
             tok_s=round(n_tps, 2), **n_lat_f,
             useful_tokens=int(n_useful),
             metrics=noshare.obs.snapshot(),
             **paged_fields(noshare, nspec0))
        pf = paged_fields(share, sspec0, spfx0)
        emit("serve_paged_prefix", sh_wall / max(sh_useful, 1) * 1e6,
             f"{sh_tps:.1f} tok/s {h_lat} "
             f"useful={sh_useful} page={share.page_size} "
             f"hit={pf['cache_hit_rate']:.0%} "
             f"saved={pf['admitted_tokens_saved']}tok "
             f"vs-noshare={sh_tps / max(n_tps, 1e-9):.2f}x",
             tok_s=round(sh_tps, 2), **h_lat_f,
             useful_tokens=int(sh_useful),
             metrics=share.obs.snapshot(), **pf)

    if args.preempt:
        # preemption/restore section (docs/robustness.md): its own tiny
        # fixed workload — two low-priority hogs saturate both slots and
        # most of a deliberately small page pool, then a high-priority
        # arrival starves until the aging rule fires preemption.  The
        # victim's complete pages go into the prefix tree, so its
        # restore prefix-matches them and replays only the unshared
        # tail; every counter below is host-side deterministic (exact
        # in check_bench), and the outputs must be byte-identical to an
        # unpressured engine.  NOT comparable to serve_static.
        rng = np.random.default_rng(5)
        pe_prompts = [rng.integers(0, cfg.vocab, (12,), dtype=np.int32)
                      for _ in range(2)]
        pe_prompts.append(rng.integers(0, cfg.vocab, (17,),
                                       dtype=np.int32))
        pe_gens, pe_prios = [40, 40, 7], [0, 0, 1]

        def run_prio(engine):
            for p, g, pr in zip(pe_prompts, pe_gens, pe_prios):
                engine.submit(p, g, priority=pr)
            done, useful = {}, 0
            t0 = time.perf_counter()
            while engine.has_work:
                for req in engine.step():
                    done[req.rid] = req
                    useful += req.emitted_total
            return time.perf_counter() - t0, useful, done

        ref_eng = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=64, max_batch=2, page_size=8, decode_chunk=4,
            spec_decode=0))
        _, _, ref_done = run_prio(ref_eng)
        pre = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=64, max_batch=2, page_size=8, decode_chunk=4,
            spec_decode=0, n_pages=17, prefix_cache=True, preempt=True))
        pe_wall, pe_useful, pe_done = run_prio(pre)
        assert pe_useful == sum(pe_gens), (pe_useful, sum(pe_gens))
        for rid, req in pe_done.items():
            np.testing.assert_array_equal(
                req.output, ref_done[rid].output,
                err_msg=f"rid {rid} diverged under preemption")
        reg = pre.obs.registry
        n_pre = reg.counter("sched.preemptions").value
        restored = reg.counter("lifecycle.preempted_retried").value
        saved = pre.prefix_stats()["tokens_saved"]
        assert n_pre > 0, "preemption section never preempted"
        assert saved > 0, "restore never matched the registered pages"
        pe_tps = pe_useful / pe_wall
        emit("serve_paged_preempt", pe_wall / max(pe_useful, 1) * 1e6,
             f"{pe_tps:.1f} tok/s useful={pe_useful} "
             f"preemptions={n_pre} restored={restored} "
             f"saved={saved}tok (pressure workload, byte-exact)",
             tok_s=round(pe_tps, 2), useful_tokens=int(pe_useful),
             preemptions=int(n_pre), restored_requests=int(restored),
             admitted_tokens_saved=int(saved),
             metrics=pre.obs.snapshot())

    if args.metrics_out:
        paged.obs.write_metrics(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    paged.obs.close()

    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
