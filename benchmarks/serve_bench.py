"""Serving benchmark: paged continuous batching vs the static-batch
baseline on a mixed-length workload.

Workload: ``--requests`` prompts with lengths in [prompt_len/2,
prompt_len] and *heavy-tailed* generation budgets (75% short answers,
25% long ones up to ``--gen``) — the output-length skew real serving
traffic has.  The static engine processes requests in submission-order
batches, left-padding prompts to the batch max and decoding every batch
member to the batch's largest budget (tokens past a request's own budget
are discarded — the lock-step waste continuous batching removes).  The
paged engine streams the same requests through its decode slots,
admitting by free-page budget and evicting the moment a request
finishes.

Every run — ``--smoke`` included — uses a serving-scale reduced config
(d_model 256): on the tiny test config per-step compute is smaller than
a host dispatch and the comparison would measure dispatch counts, not
scheduling.  ``--smoke`` only shrinks the *workload* to CI size.

Reports decode tokens/sec (useful tokens only) and p50/p95 per-token
step latency.  CSV contract: ``name,us_per_call,derived``.

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_json
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve.engine import (DecodeEngine, PagedEngine, PagedServeConfig,
                                ServeConfig)


def make_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                  seed: int = 0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(max(1, prompt_len // 2), prompt_len + 1,
                        n_requests)
    # heavy-tailed budgets: mostly short answers, occasional stragglers
    short = rng.integers(2, max(3, gen // 8), n_requests)
    long = rng.integers(max(2, gen // 2), gen + 1, n_requests)
    gens = np.where(rng.random(n_requests) < 0.75, short, long)
    prompts = [rng.integers(0, cfg.vocab, (int(L),), dtype=np.int32)
               for L in lens]
    return prompts, [int(g) for g in gens]


def run_static(engine, prompts, gens, max_batch: int):
    """Submission-order batches, padded prompts, lock-step decode."""
    useful = 0
    step_times = []
    t0 = time.perf_counter()
    for i in range(0, len(prompts), max_batch):
        chunk_p = prompts[i:i + max_batch]
        chunk_g = gens[i:i + max_batch]
        width = max(p.shape[0] for p in chunk_p)
        batch = np.zeros((len(chunk_p), width), np.int32)
        for j, p in enumerate(chunk_p):        # right-aligned (left pad)
            batch[j, width - p.shape[0]:] = p
        n_tok = max(chunk_g)
        tb = time.perf_counter()
        engine.generate(batch, n_tok)
        dt = time.perf_counter() - tb
        step_times += [dt / n_tok] * n_tok     # lock-step: uniform
        useful += sum(chunk_g)
    wall = time.perf_counter() - t0
    return wall, useful, step_times


def run_paged(engine, prompts, gens):
    for p, g in zip(prompts, gens):
        engine.submit(p, g)
    useful = 0
    step_times = []
    t0 = time.perf_counter()
    while engine.has_work:
        tb = time.perf_counter()
        for req in engine.step():
            useful += req.generated
        dt = time.perf_counter() - tb
        # one scheduler visit emits up to decode_chunk tokens per slot
        # (more with speculative decode); normalize to per-token latency
        step_times += [dt / max(engine.last_step_tokens, 1)] * \
            max(engine.last_step_tokens, 1)
    wall = time.perf_counter() - t0
    return wall, useful, step_times


def paged_fields(engine, spec_before=None):
    """Per-engine configuration + speculative-decode acceptance stats
    for the JSON record (delta against a pre-warmup snapshot so warmup
    verify calls don't pollute the measured run)."""
    fields = {"page_size": int(engine.page_size),
              "prefill_chunk": int(engine.prefill_chunk),
              "spec_decode": int(engine.spec)}
    if engine.spec:
        st = engine.spec_stats()
        calls = st["verify_calls"] - (spec_before or {}).get(
            "verify_calls", 0)
        toks = st["tokens"] - (spec_before or {}).get("tokens", 0)
        fields["spec_verify_calls"] = int(calls)
        fields["spec_mean_accepted"] = round(toks / calls, 3) if calls \
            else 0.0
    return fields


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=96)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (same serving-scale model)")
    ap.add_argument("--fuse", action="store_true",
                    help="also run the paged engine with cross-op "
                         "fused kernels (docs/fusion.md) and report a "
                         "fused-vs-unfused section")
    ap.add_argument("--spec", type=int, default=2,
                    help="draft tokens per speculative decode step for "
                         "the paged engine (0 -> off)")
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="decode steps fused per scheduler visit; small "
                         "chunks turn slots over faster on heavy-tailed "
                         "budgets (finished slots leave, queued work "
                         "enters, between chunks)")
    ap.add_argument("--prefill-chunk", type=int, default=-1,
                    help="paged prefill chunk (-1 -> auto-sized from "
                         "the VMEM blocking model, 0 -> whole-prompt "
                         "joins)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every record as machine-readable "
                         "JSON (the BENCH_serve.json trajectory file)")
    args = ap.parse_args()
    if args.smoke:
        # large enough that per-step latency percentiles are taken over
        # dozens of steps, the heavy-tailed budget draw can't collapse
        # the whole workload to a handful of useful tokens (the old
        # 6-request/gen-8 draw bottomed out at useful=23), and the
        # batch is wide enough that lock-step padding waste — the thing
        # continuous batching exists to remove — actually shows up
        args.requests, args.gen, args.prompt_len = 16, 48, 16
        args.max_seq, args.max_batch = 64, 4

    cfg = dataclasses.replace(get_reduced(args.arch), dtype=jnp.float32)
    # serving-scale reduced model: per-step compute must dominate host
    # dispatch for the throughput comparison to mean anything
    cfg = dataclasses.replace(cfg, d_model=256, n_layers=4,
                              n_heads=8, n_kv_heads=4, d_ff=1024,
                              vocab=4096)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts, gens = make_workload(cfg, args.requests, args.prompt_len,
                                  args.gen)

    chunk = None if args.prefill_chunk < 0 else args.prefill_chunk
    static = DecodeEngine(cfg, params, ServeConfig(max_seq=args.max_seq))
    paged = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=args.max_seq, max_batch=args.max_batch,
        page_size=args.page_size or None, prefill_chunk=chunk,
        spec_decode=args.spec, decode_chunk=args.decode_chunk))

    # warm the compile caches outside the timed region: one full pass of
    # the same workload per engine (compiles are keyed by batch width,
    # token budget and prefill bucket — the workload exercises them all)
    run_static(static, prompts, gens, args.max_batch)
    run_paged(paged, prompts, gens)
    spec0 = paged.spec_stats() if paged.spec else None

    s_wall, s_useful, s_steps = run_static(static, prompts, gens,
                                           args.max_batch)
    p_wall, p_useful, p_steps = run_paged(paged, prompts, gens)
    page = paged.page_size
    assert p_useful == sum(gens), (p_useful, sum(gens))

    s_tps = s_useful / s_wall
    p_tps = p_useful / p_wall
    s50, s95 = np.percentile(np.asarray(s_steps) * 1e6, [50, 95])
    p50, p95 = np.percentile(np.asarray(p_steps) * 1e6, [50, 95])
    emit("serve_static", s_wall / max(s_useful, 1) * 1e6,
         f"{s_tps:.1f} tok/s p50={s50:.0f}us p95={s95:.0f}us "
         f"useful={s_useful}",
         tok_s=round(s_tps, 2), p50_us=round(s50, 1),
         p95_us=round(s95, 1), useful_tokens=int(s_useful))
    emit("serve_paged", p_wall / max(p_useful, 1) * 1e6,
         f"{p_tps:.1f} tok/s p50={p50:.0f}us p95={p95:.0f}us "
         f"useful={p_useful} page={page} chunk={paged.prefill_chunk} "
         f"spec={paged.spec} speedup={p_tps / max(s_tps, 1e-9):.2f}x",
         tok_s=round(p_tps, 2), p50_us=round(p50, 1),
         p95_us=round(p95, 1), useful_tokens=int(p_useful),
         **paged_fields(paged, spec0))

    if args.fuse:
        # fused-vs-unfused paged section: same workload, same slots,
        # cross-op fused kernels on the hot path; greedy decoding makes
        # the outputs comparable token-for-token with the run above
        fused = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=args.max_seq, max_batch=args.max_batch,
            page_size=args.page_size or None, fuse=True,
            prefill_chunk=chunk, spec_decode=args.spec,
            decode_chunk=args.decode_chunk))
        run_paged(fused, prompts, gens)          # warm compiles
        fspec0 = fused.spec_stats() if fused.spec else None
        f_wall, f_useful, f_steps = run_paged(fused, prompts, gens)
        assert f_useful == sum(gens), (f_useful, sum(gens))
        f_tps = f_useful / f_wall
        f50, f95 = np.percentile(np.asarray(f_steps) * 1e6, [50, 95])
        emit("serve_paged_fused", f_wall / max(f_useful, 1) * 1e6,
             f"{f_tps:.1f} tok/s p50={f50:.0f}us p95={f95:.0f}us "
             f"useful={f_useful} page={fused.page_size} "
             f"vs-unfused={f_tps / max(p_tps, 1e-9):.2f}x",
             tok_s=round(f_tps, 2), p50_us=round(f50, 1),
             p95_us=round(f95, 1), useful_tokens=int(f_useful),
             **paged_fields(fused, fspec0))

    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
